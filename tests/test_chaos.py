"""Tests for the chaos-hardened execution plane: the seeded fault-injection
engine (spec round-trip, bit-identical replay), the transient-vs-terminal
retry taxonomy, torn-write recovery on both store backends, heartbeat-death
fencing, worker self-fencing on a sick store path, charged voluntary
release, broker degraded mode, the concurrent-reclaimer race, and the
multi-host ``python -m repro.core.workers`` entry point."""

import errno
import json
import multiprocessing as mp
import os
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core import accounting, chaos
from repro.core.chaos import (
    ChaosEngine,
    ChaosError,
    ChaosRule,
    ChaosSpec,
    run_chaos_component,
)
from repro.core.component import PipelineError
from repro.core.harness import BenchmarkSpec
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.retry import (
    RetryPolicy,
    call_with_retry,
    is_transient,
    retry_counters,
)
from repro.core.store import ResultStore
from repro.core.synthetic import BlockingHarness, SpinHarness
from repro.core.workers import (
    CampaignBroker,
    WorkerConfig,
    _execute_payload,
    cell_payload,
    host_of,
    worker_identity,
)
from repro.core.workqueue import WorkQueue

REPO = Path(__file__).resolve().parent.parent
SPAWN = mp.get_context("spawn")


@pytest.fixture(autouse=True)
def _no_chaos_leak():
    """Chaos state is process-global; never let a scenario outlive its test."""
    yield
    chaos.install(None)
    os.environ.pop(chaos.ENV_VAR, None)


def _install(spec_text):
    return chaos.install(ChaosEngine(ChaosSpec.parse(spec_text)))


def _specs(n):
    return [BenchmarkSpec(arch=f"arch{i}", shape="train_4k", system="sysA")
            for i in range(n)]


def _payloads(n, prefix="q"):
    return [cell_payload(s, {"prefix": prefix}, cell_index=i)
            for i, s in enumerate(_specs(n))]


def _canon(store, prefix):
    return sorted(json.dumps(accounting.strip_volatile(r.to_dict()),
                             sort_keys=True)
                  for r in store.query(prefix))


# ---------------------------------------------------------------------------
# spec parse / render
# ---------------------------------------------------------------------------

def test_spec_parse_render_roundtrip():
    text = ("seed=42;site=store.append:kind=eio:at=2;"
            "site=queue.*:kind=stall:p=0.25:times=3:dur=0.1;"
            "site=queue.reclaim:kind=skew:skew=120;"
            "site=store.append:kind=torn:frac=0.3")
    spec = ChaosSpec.parse(text)
    assert spec.seed == 42 and len(spec.rules) == 4
    assert spec.rules[0] == ChaosRule(site="store.append", kind="eio", at=2)
    assert spec.rules[1].p == 0.25 and spec.rules[1].times == 3
    assert spec.rules[2].skew == 120.0
    assert spec.rules[3].frac == 0.3
    # Canonical round-trip: parse(render()) is the identity.
    assert ChaosSpec.parse(spec.render()) == spec


@pytest.mark.parametrize("bad", [
    "seed=forty",
    "site=store.append",                      # no kind
    "kind=eio",                               # no site
    "site=x:kind=meteor",                     # unknown kind
    "site=x:kind=eio:zap=1",                  # unknown key
    "site=x:kind=eio:p=not-a-float",
    "site=x:kind=eio:junk",                   # token without '='
])
def test_spec_parse_rejects_malformed_clauses(bad):
    with pytest.raises(PipelineError, match="chaos"):
        ChaosSpec.parse(bad)


# ---------------------------------------------------------------------------
# engine determinism
# ---------------------------------------------------------------------------

def _drive(engine):
    """A fixed mixed call sequence; returns the engine's decision log."""
    for i in range(30):
        try:
            engine.trip("store.append")
        except ChaosError:
            pass
        try:
            engine.trip("queue.claim")
        except ChaosError:
            pass
        engine.torn("store.append", 100 + i)
        engine.skew("queue.reclaim")
    return list(engine.log)


def test_replay_from_the_same_spec_is_bit_identical():
    text = ("seed=7;site=store.append:kind=eio:p=0.3;"
            "site=queue.*:kind=enospc:p=0.5:times=4;"
            "site=store.append:kind=torn:p=0.4:frac=0.5;"
            "site=queue.reclaim:kind=skew:p=0.2:skew=30")
    log1 = _drive(ChaosEngine(ChaosSpec.parse(text)))
    log2 = _drive(ChaosEngine(ChaosSpec.parse(text)))
    assert log1 == log2 and log1  # identical AND non-trivial
    # A different seed explores a different fault schedule.
    other = _drive(ChaosEngine(ChaosSpec.parse(text.replace("seed=7",
                                                            "seed=8"))))
    assert other != log1


def test_at_and_times_gates():
    eng = ChaosEngine(ChaosSpec.parse("site=s:kind=eio:at=3"))
    fired = []
    for i in range(5):
        try:
            eng.trip("s")
        except ChaosError as e:
            fired.append((i, e.errno))
    assert fired == [(2, errno.EIO)]  # only the 3rd call

    eng = ChaosEngine(ChaosSpec.parse("site=s:kind=enospc:times=2"))
    hits = 0
    for _ in range(6):
        try:
            eng.trip("s")
        except ChaosError:
            hits += 1
    assert hits == 2  # budget-bounded


def test_module_hooks_are_noops_without_an_engine():
    chaos.install(None)
    chaos.trip("store.append")  # must not raise
    assert chaos.torn("store.append", 100) is None
    assert chaos.skew("queue.reclaim") == 0.0


def test_component_installs_and_exports_to_env():
    out = run_chaos_component(
        {"spec": "site=store.append:kind=eio:at=1", "seed": 99,
         "export": True}, None)
    assert out["seed"] == 99
    engine = chaos.current()
    assert engine is not None and engine.spec.seed == 99
    # The exported env replays the identical scenario in a fresh process.
    exported = os.environ[chaos.ENV_VAR]
    assert ChaosSpec.parse(exported) == engine.spec


# ---------------------------------------------------------------------------
# retry taxonomy + policy
# ---------------------------------------------------------------------------

def test_taxonomy_transient_vs_terminal():
    assert is_transient(OSError(errno.EIO, "io"))
    assert is_transient(OSError(errno.ENOSPC, "full"))
    assert is_transient(ChaosError(errno.EIO, "s", 1))
    # O_EXCL protocol signals must never be blind-retried.
    assert not is_transient(FileExistsError(errno.EEXIST, "lease"))
    assert not is_transient(FileNotFoundError(errno.ENOENT, "gone"))
    assert not is_transient(PermissionError(errno.EACCES, "ro"))
    assert not is_transient(ValueError("not I/O at all"))


def test_call_with_retry_recovers_then_reports_counters():
    retry_counters(reset=True)
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise OSError(errno.EIO, "blip")
        return "ok"

    assert call_with_retry(flaky, label="t.flaky", sleep=lambda s: None) == "ok"
    assert len(attempts) == 3
    counters = retry_counters()
    assert counters["t.flaky"] == {"calls": 1, "retries": 1, "exhausted": 0}


def test_call_with_retry_terminal_raises_immediately():
    attempts = []

    def denied():
        attempts.append(1)
        raise PermissionError(errno.EACCES, "read-only store")

    with pytest.raises(PermissionError):
        call_with_retry(denied, label="t.denied", sleep=lambda s: None)
    assert len(attempts) == 1  # no retry on terminal errors


def test_call_with_retry_exhaustion_raises_last_transient():
    retry_counters(reset=True)
    policy = RetryPolicy(tries=3, base_s=0.0)

    def sick():
        raise OSError(errno.ENOSPC, "still full")

    with pytest.raises(OSError) as exc:
        call_with_retry(sick, label="t.sick", policy=policy,
                        sleep=lambda s: None)
    assert exc.value.errno == errno.ENOSPC
    assert retry_counters()["t.sick"]["exhausted"] == 1


def test_policy_delay_is_bounded_equal_jitter():
    import random

    policy = RetryPolicy(tries=5, base_s=0.1, factor=2.0, max_s=0.5)
    rng = random.Random(0)
    for attempt in range(8):
        ceiling = min(0.5, 0.1 * 2 ** attempt)
        for _ in range(20):
            d = policy.delay(attempt, rng)
            assert ceiling / 2.0 <= d <= ceiling


# ---------------------------------------------------------------------------
# injected faults against the real store / queue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_torn_store_write_is_retried_and_parity_holds(tmp_path, backend):
    """A torn append (partial bytes then EIO) must be survived by the
    store's bounded retry, and the surviving content must be canonically
    identical to a fault-free run."""
    clean = ResultStore(tmp_path / "clean", backend=backend)
    ExecutionOrchestrator(inputs={"prefix": "p"}, harness=SpinHarness(iters=50),
                          store=clean).run_collection(_specs(2))

    _install("seed=1;site=store.append:kind=torn:at=1:frac=0.4")
    faulty = ResultStore(tmp_path / "faulty", backend=backend)
    ExecutionOrchestrator(inputs={"prefix": "p"}, harness=SpinHarness(iters=50),
                          store=faulty).run_collection(_specs(2))
    chaos.install(None)

    assert len(faulty.query("p")) == 2
    assert _canon(faulty, "p") == _canon(clean, "p")


def test_enospc_on_claim_is_retried(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    _install("site=queue.claim:kind=enospc:at=1")
    claim = call_with_retry(lambda: q.claim_next("w1"),
                            label="queue.claim", sleep=lambda s: None)
    assert claim is not None and claim[0] == 0 and claim[2] == 1


def test_persistent_eio_on_claim_surfaces_after_bounded_retries(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    _install("site=queue.claim:kind=eio")  # unbounded: every call fails
    with pytest.raises(OSError):
        call_with_retry(lambda: q.claim_next("w1"),
                        label="queue.claim", sleep=lambda s: None)


def test_heartbeat_death_sets_lost_and_fences(tmp_path):
    """Persistent heartbeat I/O failure must fence the cell (lost set), not
    silently kill the thread while the worker keeps executing."""
    from repro.core.workers import _Heartbeat

    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    assert q.claim_next("w1") is not None
    _install("site=queue.heartbeat:kind=eio")  # every heartbeat fails
    beat = _Heartbeat(q, 0, 0.01)
    beat.start()
    assert beat.lost.wait(10.0), "heartbeat never fenced on persistent I/O failure"
    beat.stop()
    beat.join(timeout=5)
    # The lease itself is still there — fencing is the worker's job.
    assert q.lease_info(0) is not None


def test_heartbeat_reports_vanished_lease_without_chaos(tmp_path):
    from repro.core.workers import _Heartbeat

    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    assert q.claim_next("w1") is not None
    beat = _Heartbeat(q, 0, 0.01)
    beat.start()
    (tmp_path / "q" / "leases" / "00000.lease").unlink()
    assert beat.lost.wait(10.0)
    beat.stop()
    beat.join(timeout=5)


def test_skewed_clock_reclaim_charges_exactly_once(tmp_path):
    """A reclaimer whose clock runs fast sees every live lease as expired —
    the protocol must still charge the journal exactly once."""
    q = WorkQueue(tmp_path / "q", lease_timeout=30.0).create(_payloads(1))
    assert q.claim_next("w1") is not None
    _install("site=queue.reclaim:kind=skew:skew=3600")
    assert q.reclaim_expired() == [0]
    chaos.install(None)
    journal = q.reclaim_journal()
    assert len(journal) == 1 and journal[0]["idx"] == 0
    # No skew, no phantom second reclaim; the cell claims again at attempt 2.
    assert q.reclaim_expired() == []
    claim = q.claim_next("w2")
    assert claim is not None and claim[2] == 2


def test_store_append_failure_marks_store_failed(tmp_path):
    """A store path that stays sick through every bounded retry must surface
    as ``store_failed`` so the worker self-fences instead of recording a
    terminal FAILED marker for a healthy cell."""
    store = ResultStore(tmp_path / "s")
    payload = cell_payload(_specs(1)[0], {"prefix": "sick"})
    payload["task_uid"] = "sick:0"
    _install("site=store.append:kind=eio")  # unbounded
    result = _execute_payload(payload, store=store, harness=SpinHarness(iters=50),
                              worker_id="host:1:w1", attempt=1,
                              fence=lambda: True, resource_scope="thread")
    chaos.install(None)
    assert result["store_failed"] is True
    assert len(store.query("sick")) == 0  # nothing half-landed


def test_charged_release_exhausts_max_attempts_terminally(tmp_path):
    """A cell whose every execution self-fences must terminate via the same
    max-attempts budget as reclaim — bounded, not bouncing forever."""
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    for attempt in range(1, 4):
        claim = q.claim_next(f"w{attempt}")
        assert claim is not None and claim[2] == attempt
        assert q.release(0, f"w{attempt}", attempt, charge=True, max_attempts=3)
    journal = q.reclaim_journal()
    assert len(journal) == 3 and all(e.get("released") for e in journal)
    result = q.results()[0]
    assert "self-fenced" in result["error"] and result["attempts"] == 3
    assert q.claim_next("w4") is None  # terminally done


def test_release_by_non_owner_is_refused(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    assert q.claim_next("w1") is not None
    assert q.release(0, "intruder", 1) is False
    assert q.release(0, "w1", 99) is False     # wrong attempt = stale claim
    assert q.lease_info(0)["worker"] == "w1"   # untouched
    assert q.release(0, "w1", 1) is True       # the owner may release
    assert q.lease_info(0) is None


def test_broker_degraded_mode_reports_instead_of_crashing(tmp_path):
    """An unusable queue root yields synthesized per-cell failures — a
    broker embedded in the daemon must report a sick filesystem, not die."""
    store = ResultStore(tmp_path / "s")
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("file where the queue parent should be")
    broker = CampaignBroker(store, workers=1,
                            queue_root=blocker / "q")
    payloads = _payloads(2)
    for i, p in enumerate(payloads):
        p["task_uid"] = f"deg:{i}"
    results = broker.run(payloads, harness=SpinHarness(iters=50))
    assert sorted(results) == [0, 1]
    for idx, r in results.items():
        assert r["readiness"] == 0 and "queue root unusable" in r["error"]
        assert r["task_uid"] == f"deg:{idx}"


# ---------------------------------------------------------------------------
# concurrent reclaimers (two racing brokers)
# ---------------------------------------------------------------------------

def _racing_reclaimer(queue_root, barrier, out):
    q = WorkQueue(queue_root, lease_timeout=0.2)
    barrier.wait(timeout=30)
    out.extend(q.reclaim_expired())


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_concurrent_reclaimers_charge_exactly_one_attempt(tmp_path, backend):
    """Two independent reclaimers (the broker's monitor loop on two hosts)
    race ``reclaim_expired`` over the same expired lease: the flock
    arbitration must let exactly one win — one journal entry, one charged
    attempt, and the subsequent retry both claims at attempt 2 and lands
    exactly one store record."""
    store = ResultStore(tmp_path / "s", backend=backend)
    q = WorkQueue(tmp_path / "q", lease_timeout=0.2).create(
        _payloads(1, prefix="race"))
    assert q.claim_next("dead-worker") is not None
    time.sleep(0.5)  # let the lease expire

    mgr = mp.Manager()
    out_a, out_b = mgr.list(), mgr.list()
    barrier = mgr.Barrier(2)
    procs = [
        mp.Process(target=_racing_reclaimer,
                   args=(str(tmp_path / "q"), barrier, out))
        for out in (out_a, out_b)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=30)
        assert p.exitcode == 0

    # Exactly one reclaimer won the cell; the journal charged one attempt.
    assert sorted(list(out_a) + list(out_b)) == [0]
    journal = q.reclaim_journal()
    assert len(journal) == 1 and journal[0]["worker"] == "dead-worker"

    # No double-claim afterwards: one worker gets attempt 2, the other gets
    # nothing, and exactly one report lands in the store.
    claim = q.claim_next("retry-a")
    assert claim is not None and claim[2] == 2
    assert q.claim_next("retry-b") is None
    payload = dict(claim[1])
    result = _execute_payload(payload, store=store,
                              harness=SpinHarness(iters=50),
                              worker_id="host:1:retry-a", attempt=2,
                              resource_scope="thread")
    assert q.complete(0, result)
    assert len(store.query("race")) == 1


# ---------------------------------------------------------------------------
# multi-host drain: python -m repro.core.workers
# ---------------------------------------------------------------------------

def _cli_env(host):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["EXACB_HOST"] = host
    return env


def _wait_for(pred, timeout, what):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        got = pred()
        if got:
            return got
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


@pytest.mark.slow
def test_two_hosts_drain_one_campaign_with_provenance(tmp_path):
    """The acceptance scenario: a broker-published queue drained by two
    out-of-band ``python -m repro.core.workers`` processes with distinct
    simulated host identities; per-host provenance must land in the lease
    files, done markers, store reports, and the worker registry that
    ``daemon-status`` renders."""
    from repro.core.daemon import worker_liveness

    store = ResultStore(tmp_path / "store")
    sentinels = tmp_path / "sentinels"
    specs = _specs(2)
    payloads = [cell_payload(s, {"prefix": "mh"}, cell_index=i)
                for i, s in enumerate(specs)]
    broker = CampaignBroker(store, workers=2, name="mh", lease_timeout=10.0,
                            keep_queue=True)
    queue = broker.publish(
        payloads,
        harness=BlockingHarness(sentinel_dir=str(sentinels), timeout_s=60.0))
    assert (broker.queue_root / "worker_config.json").exists()

    def _spawn_host(host, label):
        return subprocess.Popen(
            [sys.executable, "-m", "repro.core.workers",
             str(broker.queue_root), "--label", label],
            env=_cli_env(host), cwd=str(REPO),
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)

    # hostA claims cell 0 and blocks; only then does hostB join, so it can
    # only claim cell 1 — both hosts deterministically do real work.
    pa = _spawn_host("hostA", "wa")
    pb = None
    try:
        _wait_for(lambda: next(iter(
            sentinels.glob(f"started.{specs[0].cell}.*")), None),
            30.0, "hostA to start cell 0")
        pb = _spawn_host("hostB", "wb")
        _wait_for(lambda: next(iter(
            sentinels.glob(f"started.{specs[1].cell}.*")), None),
            30.0, "hostB to start cell 1")
        (sentinels / "release").write_text("go")
        assert pa.wait(timeout=60) == 0
        assert pb.wait(timeout=60) == 0
    finally:
        for p in (pa, pb):
            if p is not None and p.poll() is None:
                p.kill()
                p.wait(timeout=10)

    assert queue.finished()
    results = queue.results()
    assert host_of(results[0]["worker"]) == "hostA"
    assert host_of(results[1]["worker"]) == "hostB"
    assert results[0]["host"] == "hostA" and results[1]["host"] == "hostB"

    # Store-level provenance: each report names the host that produced it.
    by_host = {r.parameter["host"] for r in store.query("mh")}
    assert by_host == {"hostA", "hostB"}
    for r in store.query("mh"):
        worker = r.parameter["worker"]
        assert worker.count(":") == 2 and host_of(worker) == r.parameter["host"]

    # Registry + daemon-status surface: both hosts, with liveness.
    registry = queue.worker_registry(alive_within=3600)
    assert {w["host"] for w in registry} == {"hostA", "hostB"}
    live = worker_liveness(store.root)
    assert set(live["hosts"]) == {"hostA", "hostB"}
    assert all(h["workers"] == 1 for h in live["hosts"].values())

    # Host is volatile for parity purposes: two runs on different hosts
    # still canonicalize identically.
    for r in store.query("mh"):
        canon = accounting.strip_volatile(r.to_dict())
        assert "host" not in canon["parameter"]


def test_cli_without_published_config_exits_2(tmp_path):
    from repro.core.workers import main as workers_main

    assert workers_main([str(tmp_path / "nowhere")]) == 2


def test_worker_identity_shape():
    wid = worker_identity("w7")
    host, pid, label = wid.split(":")
    assert host == host_of(wid) and int(pid) == os.getpid() and label == "w7"
    os.environ["EXACB_HOST"] = "simulated"
    try:
        assert host_of(worker_identity("x")) == "simulated"
    finally:
        os.environ.pop("EXACB_HOST", None)
