"""Unit tests for the roofline computation (deliverable g math)."""

import pytest

from repro import configs
from repro.core import roofline
from repro.distributed.hlo import HloCost
from repro.hardware import SINGLE_POD


def _mk(cost_kw, shape_kind="train", arch="glm4-9b", gb=256, seq=4096):
    cfg = configs.get_config(arch)
    cost = HloCost(**cost_kw)
    return roofline.compute(
        cfg=cfg, arch=arch, shape_name="x", shape_kind=shape_kind,
        seq_len=seq, global_batch=gb, system=SINGLE_POD, strategy="tp_dp",
        cost=cost, hbm_required=8e9, state_bytes=0.0,
    )


def test_terms_and_dominant():
    r = _mk({"flops": 197e12, "bytes": 819e9 * 2, "collective_bytes": 50e9 / 2})
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.step_time == pytest.approx(2.0)
    assert r.fits  # 8 GB < 16 GB


def test_model_flops_train_vs_decode():
    n_tok_train = 256 * 4096
    mf_train = roofline.model_flops(configs.get_config("glm4-9b"), "train", n_tok_train)
    mf_dec = roofline.model_flops(configs.get_config("glm4-9b"), "decode", 128)
    # train = 6ND, decode = 2ND with D=tokens.
    assert mf_train / n_tok_train == pytest.approx(3 * mf_dec / 128)


def test_moe_active_params_reduce_model_flops():
    dense_like = roofline.model_flops(configs.get_config("deepseek-v3-671b"), "train", 1000)
    from repro.models import params as P

    n_act = P.non_embedding_param_count(configs.get_config("deepseek-v3-671b"), active_only=True)
    n_tot = P.non_embedding_param_count(configs.get_config("deepseek-v3-671b"))
    assert dense_like == pytest.approx(6 * n_act * 1000)
    assert n_act < 0.1 * n_tot  # top-8 of 256 experts


def test_roofline_fraction_bounds():
    # Perfectly balanced, all-useful cell: fraction near its definition cap.
    cfg = configs.get_config("glm4-9b")
    from repro.models import params as P

    n = P.non_embedding_param_count(cfg, active_only=True)
    ntok = 256 * 4096
    useful_flops_per_dev = 6.0 * n * ntok / 256
    r = _mk({"flops": useful_flops_per_dev, "bytes": 1e9, "collective_bytes": 0.0})
    assert r.useful_ratio == pytest.approx(1.0, rel=1e-6)
    assert 0 < r.roofline_fraction <= 1.000001


def test_metrics_keys_cover_readiness_contract():
    from repro.core.readiness import INSTRUMENTED_METRICS

    r = _mk({"flops": 1e12, "bytes": 1e12, "collective_bytes": 1e9})
    m = r.metrics()
    for k in INSTRUMENTED_METRICS:
        assert k in m, k
