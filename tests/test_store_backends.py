"""Tests for the pluggable result-store backends: concurrent append safety
(the _next_seq write-race regression), manifest-index queries, the
mtime-invalidated cache, and dir/jsonl equivalence."""

import json
import threading

import pytest

from repro.core.protocol import DataEntry, new_report
from repro.core.store import DirBackend, JsonlBackend, ResultStore, StoreError


def _mk_report(system="jedi", variant="v", metrics=None, ts=None):
    r = new_report(system=system, variant=variant, usecase="u", pipeline_id="p1")
    if ts is not None:
        r.experiment.timestamp = ts
    r.data.append(DataEntry(success=True, runtime=1.0, metrics=metrics or {}))
    return r


@pytest.fixture(params=["dir", "jsonl"])
def any_store(request, tmp_path):
    return ResultStore(tmp_path, backend=request.param)


# ---------------------------------------------------------------------------
# backend-generic behavior
# ---------------------------------------------------------------------------

def test_append_query_latest(any_store):
    any_store.append("p", _mk_report(variant="a", ts=1.0))
    any_store.append("p", _mk_report(variant="b", ts=2.0))
    any_store.append("p", _mk_report(variant="a", ts=3.0))
    assert len(any_store.query("p")) == 3
    assert len(any_store.query("p", variant="a")) == 2
    assert any_store.latest("p").experiment.timestamp == 3.0
    assert any_store.latest("p", variant="b").experiment.timestamp == 2.0
    assert any_store.query("p", since=1.5, until=2.5)[0].experiment.variant == "b"
    assert any_store.prefixes() == ["p"]


def test_ingest_external_breaks_trust(any_store):
    any_store.ingest_external("x", _mk_report().to_dict())
    assert any_store.query("x")[0].reporter.chain_of_trust is False
    assert any_store.query("x", trusted_only=True) == []


def test_query_cache_sees_new_appends(any_store):
    any_store.append("p", _mk_report(ts=1.0))
    assert len(any_store.query("p")) == 1  # populates the cache
    any_store.append("p", _mk_report(ts=2.0))
    assert len(any_store.query("p")) == 2  # fingerprint change invalidates


def test_concurrent_appenders_one_prefix(any_store):
    """Regression for the _next_seq write race: two writers globbing the same
    directory used to allocate the same sequence and silently clobber."""
    n_threads, per_thread = 8, 5
    errors = []
    barrier = threading.Barrier(n_threads)

    def writer(i):
        try:
            barrier.wait(timeout=10)
            for j in range(per_thread):
                any_store.append("race", _mk_report(
                    variant=f"w{i}.{j}", ts=float(i * per_thread + j)))
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    reports = any_store.query("race")
    assert len(reports) == n_threads * per_thread  # nothing clobbered
    variants = {r.experiment.variant for r in reports}
    assert len(variants) == n_threads * per_thread
    # Sequence numbers are unique and gap-free.
    index = any_store.backend.scan("race")
    assert sorted(e.seq for e in index) == list(range(n_threads * per_thread))


def test_empty_prefix_rejected(any_store):
    with pytest.raises(StoreError):
        any_store.append("", _mk_report())


# ---------------------------------------------------------------------------
# backend equivalence
# ---------------------------------------------------------------------------

def test_backends_query_byte_identical(tmp_path):
    dir_store = ResultStore(tmp_path / "d", backend="dir")
    jsonl_store = ResultStore(tmp_path / "j", backend="jsonl")
    for i in range(10):
        r = _mk_report(variant=f"v{i % 3}", metrics={"m": float(i)}, ts=float(i))
        dir_store.append("eq", r)
        jsonl_store.append("eq", r)
    for kw in ({}, {"variant": "v1"}, {"since": 3.0, "until": 7.0}):
        a = [r.to_json() for r in dir_store.query("eq", **kw)]
        b = [r.to_json() for r in jsonl_store.query("eq", **kw)]
        assert a == b and a  # byte-identical, and non-empty


# ---------------------------------------------------------------------------
# dir-backend specifics
# ---------------------------------------------------------------------------

def test_dir_layout_unchanged_and_tamper_detected(tmp_path):
    store = ResultStore(tmp_path)
    assert isinstance(store.backend, DirBackend)
    p1 = store.append("t", _mk_report(metrics={"m": 1.0}, ts=1.0))
    store.append("t", _mk_report(ts=2.0))
    assert p1.name.split(".")[0] == "00000000" and p1.name.endswith(".json")
    assert len(store.query("t")) == 2
    doc = json.loads(p1.read_text())
    doc["data"][0]["runtime"] = 999.0
    p1.write_text(json.dumps(doc))
    assert len(store.query("t")) == 1  # cache invalidated AND corrupt skipped


def test_dir_manifest_rebuilt_for_preexisting_store(tmp_path):
    # A store written without a manifest (or with a stale one) still queries.
    store = ResultStore(tmp_path)
    store.append("t", _mk_report(variant="a", ts=1.0))
    store.append("t", _mk_report(variant="b", ts=2.0))
    (tmp_path / "t" / "_manifest.jsonl").unlink()
    fresh = ResultStore(tmp_path)
    assert [r.experiment.variant for r in fresh.query("t")] == ["a", "b"]
    assert fresh.latest("t", variant="a").experiment.timestamp == 1.0


# ---------------------------------------------------------------------------
# jsonl-backend specifics
# ---------------------------------------------------------------------------

def test_jsonl_compact_layout(tmp_path):
    store = ResultStore(tmp_path, backend="jsonl")
    assert isinstance(store.backend, JsonlBackend)
    for i in range(5):
        store.append("t", _mk_report(ts=float(i)))
    assert (tmp_path / "t.jsonl").exists()
    assert len((tmp_path / "t.jsonl").read_text().splitlines()) == 5
    assert len(store.query("t")) == 5


def test_jsonl_survives_torn_tail_and_lost_index(tmp_path):
    store = ResultStore(tmp_path, backend="jsonl")
    for i in range(3):
        store.append("t", _mk_report(ts=float(i)))
    # Simulate a crash mid-append: torn trailing line, sidecar index gone.
    with open(tmp_path / "t.jsonl", "a") as f:
        f.write('{"seq": 3, "digest": "xxxx", "repo')
    (tmp_path / "t.jsonl.idx").unlink()
    fresh = ResultStore(tmp_path, backend="jsonl")
    assert len(fresh.query("t")) == 3  # intact records survive
    # And appends keep working after the rebuild.
    fresh.append("t", _mk_report(ts=9.0))
    assert fresh.latest("t").experiment.timestamp == 9.0


def test_unknown_backend_rejected(tmp_path):
    with pytest.raises(StoreError):
        ResultStore(tmp_path, backend="sqlite")
