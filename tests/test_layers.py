"""Unit tests for attention variants, the SSD scan, and optimizer numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.train import optimizer as O


def naive_attention(q, k, v, *, causal=True, window=None, prefix_len=0, scale=None):
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale or 1.0 / np.sqrt(D)
    qr = q.reshape(B, Hkv, G, T, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, k).astype(jnp.float32) * scale
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((T, T), bool)
    if causal:
        cm = j <= i
        if prefix_len:
            cm = cm | ((i < prefix_len) & (j < prefix_len))
        mask = mask & cm
    if window is not None:
        mask = mask & (j > i - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(B, Hq, T, -1).astype(q.dtype)


def _qkv(T=192, B=2, Hq=4, Hkv=2, D=16, Dv=None, seed=0):
    rng = np.random.default_rng(seed)
    Dv = Dv or D
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, Dv)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window,prefix", [(None, 0), (37, 0), (None, 70), (64, 0)])
@pytest.mark.parametrize("impl", [L.chunked_attention, L.banded_attention])
def test_attention_matches_naive(impl, window, prefix):
    q, k, v = _qkv()
    ref = naive_attention(q, k, v, window=window, prefix_len=prefix)
    got = impl(q, k, v, window=window, prefix_len=prefix, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_attention_mla_shaped_values():
    # MLA: v dim differs from qk dim.
    q, k, v = _qkv(D=24, Dv=16)
    ref = naive_attention(q, k, v)
    for impl in (L.chunked_attention, L.banded_attention):
        got = impl(q, k, v, chunk_q=64, chunk_k=64)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    T=st.integers(min_value=8, max_value=257),
    chunk=st.sampled_from([16, 64, 128]),
    window=st.sampled_from([None, 16, 100]),
    seed=st.integers(min_value=0, max_value=5),
)
def test_banded_attention_property(T, chunk, window, seed):
    """Property: banded == naive for any (T, chunk, window) combination."""
    q, k, v = _qkv(T=T, seed=seed)
    ref = naive_attention(q, k, v, window=window)
    got = L.banded_attention(q, k, v, window=window, chunk_q=chunk, chunk_k=chunk)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_sequential(xi, dt, A, Bm, Cm):
    """O(T) sequential reference for the chunked SSD scan."""
    Bsz, T, H, P = xi.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    B_h = np.repeat(np.asarray(Bm, np.float64), rep, axis=2)
    C_h = np.repeat(np.asarray(Cm, np.float64), rep, axis=2)
    x = np.asarray(xi, np.float64)
    d = np.asarray(dt, np.float64)
    a = np.asarray(A, np.float64)
    S = np.zeros((Bsz, H, N, P))
    ys = np.zeros_like(x)
    for t in range(T):
        dA = np.exp(d[:, t] * a[None, :])  # (B,H)
        S = S * dA[..., None, None] + np.einsum(
            "bhn,bhp->bhnp", B_h[:, t] * d[:, t][..., None], x[:, t]
        )
        ys[:, t] = np.einsum("bhn,bhnp->bhp", C_h[:, t], S)
    return ys


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_scan_matches_sequential(chunk):
    rng = np.random.default_rng(0)
    B, T, H, P, G, N = 2, 48, 4, 8, 2, 16
    xi = jnp.asarray(rng.standard_normal((B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, T, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, T, G, N)), jnp.float32)
    ref = ssd_sequential(xi, dt, A, Bm, Cm)
    got = L.ssd_scan_ref(xi, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Optimizer numerics
# ---------------------------------------------------------------------------

def test_q8_roundtrip_error():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 1000)) * 0.01, jnp.float32)
    enc = O.q8_encode(x)
    dec = O.q8_decode(enc, x.shape)
    err = np.max(np.abs(np.asarray(dec - x)))
    scale = np.max(np.abs(np.asarray(x)))
    assert err <= scale / 127.0 * 1.01


def test_q8_adam_tracks_f32_adam():
    """q8-state AdamW must stay close to f32-state AdamW over steps."""
    rng = np.random.default_rng(2)
    p0 = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 0.1, jnp.float32)}
    cfg32 = O.OptConfig(lr=1e-2, warmup_steps=0, schedule="constant")
    cfg8 = O.OptConfig(lr=1e-2, warmup_steps=0, schedule="constant", state_dtype="q8")
    s32, s8 = O.init(p0, cfg32), O.init(p0, cfg8)
    pa, pb = p0, p0
    for i in range(10):
        g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 0.05, jnp.float32)}
        pa, s32, _ = O.apply(g, pa, s32, cfg32)
        pb, s8, _ = O.apply(g, pb, s8, cfg8)
    diff = float(jnp.max(jnp.abs(pa["w"] - pb["w"])))
    denom = float(jnp.max(jnp.abs(pa["w"] - p0["w"])))
    assert diff < 0.1 * denom, (diff, denom)


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 1.0 + 2.0**-9, jnp.float32)  # between bf16 grid pts
    key = jax.random.key(0)
    r = O.stochastic_round_bf16(x, key).astype(jnp.float32)
    vals = np.unique(np.asarray(r))
    assert len(vals) == 2  # rounds both directions
    mean = float(jnp.mean(r))
    assert abs(mean - float(x[0])) < 2e-4  # unbiased in expectation


def test_learning_rate_schedule():
    cfg = O.OptConfig(lr=1.0, warmup_steps=10, total_steps=110, schedule="cosine")
    assert float(O.learning_rate(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(O.learning_rate(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(O.learning_rate(cfg, jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)
