"""Tests for the exaCB core (protocol, store, readiness, orchestrators,
analysis, energy) — the paper's contribution surface."""

import dataclasses
import json
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import analysis, energy
from repro.core.harness import BenchmarkSpec, Harness, Injections
from repro.core.orchestrator import (
    ExecutionOrchestrator,
    FeatureInjectionOrchestrator,
    PostProcessingOrchestrator,
)
from repro.core.protocol import (
    DataEntry,
    ProtocolError,
    Report,
    migrate,
    new_report,
)
from repro.core.readiness import Readiness, classify, verify_reproduction
from repro.core.store import ResultStore
from repro.hardware import TPU_V5E


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------

def _mk_report(system="jedi", variant="v", metrics=None, success=True, runtime=1.0):
    r = new_report(system=system, variant=variant, usecase="u", pipeline_id="p1")
    r.data.append(DataEntry(success=success, runtime=runtime, metrics=metrics or {}))
    return r


def test_protocol_roundtrip():
    r = _mk_report(metrics={"step_time_s": 0.5})
    r2 = Report.from_json(r.to_json())
    assert r2.to_dict() == r.to_dict()
    assert r2.digest() == r.digest()


def test_protocol_v1_migration():
    # v1 docs had flat metrics on the entry and no chain_of_trust.
    doc = {
        "version": "1",
        "reporter": {"system": "jedi", "pipeline_id": "x", "timestamp": 1.0},
        "experiment": {"system": "jedi", "variant": "v", "timestamp": 1.0},
        "data": [{"success": True, "runtime": 2.0, "custom_bw": 123.0}],
    }
    r = Report.from_dict(doc)
    assert r.version == "2"
    assert r.data[0].metrics["custom_bw"] == 123.0
    assert r.reporter.chain_of_trust is True


def test_protocol_rejects_bad():
    with pytest.raises(ProtocolError):
        migrate({"version": "99"})
    bad = _mk_report()
    bad.data[0].runtime = -1
    with pytest.raises(ProtocolError):
        bad.validate()


metrics_st = st.dictionaries(
    st.text(st.characters(categories=("Ll",)), min_size=1, max_size=8),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    max_size=4,
)


@settings(max_examples=50, deadline=None)
@given(
    runtime=st.floats(min_value=0, max_value=1e6, allow_nan=False),
    nodes=st.integers(min_value=1, max_value=4096),
    metrics=metrics_st,
    variant=st.text(min_size=0, max_size=12),
)
def test_protocol_roundtrip_property(runtime, nodes, metrics, variant):
    """Property: any well-formed report survives JSON round-trip exactly."""
    r = new_report(system="s", variant=variant, pipeline_id="p")
    r.data.append(DataEntry(success=True, runtime=runtime, nodes=nodes, metrics=metrics))
    r2 = Report.from_json(r.to_json())
    assert r2.to_dict() == r.to_dict()


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

def test_store_append_query_integrity(tmp_path):
    store = ResultStore(tmp_path)
    p1 = store.append("jedi.single", _mk_report(metrics={"m": 1.0}))
    store.append("jedi.single", _mk_report(variant="other"))
    assert len(store.query("jedi.single")) == 2
    assert len(store.query("jedi.single", variant="v")) == 1
    # Tamper -> integrity failure is isolated, not fatal.
    doc = json.loads(p1.read_text())
    doc["data"][0]["runtime"] = 999.0
    p1.write_text(json.dumps(doc))
    assert len(store.query("jedi.single")) == 1  # corrupt record skipped


def test_store_external_injection_breaks_trust(tmp_path):
    store = ResultStore(tmp_path)
    store.ingest_external("x", _mk_report().to_dict())
    r = store.query("x")[0]
    assert r.reporter.chain_of_trust is False
    assert store.query("x", trusted_only=True) == []


def test_store_sequence_monotonic(tmp_path):
    store = ResultStore(tmp_path)
    paths = [store.append("p", _mk_report()) for _ in range(3)]
    seqs = [int(p.name.split(".")[0]) for p in paths]
    assert seqs == [0, 1, 2]


# ---------------------------------------------------------------------------
# readiness
# ---------------------------------------------------------------------------

INSTR = {
    "hlo_flops": 1.0, "hlo_bytes": 1.0, "collective_bytes": 0.0,
    "t_compute": 1.0, "t_memory": 1.0, "t_collective": 0.0,
}


def test_readiness_ladder():
    lvl, gaps = classify(_mk_report(success=False))
    assert lvl == Readiness.FAILED
    lvl, gaps = classify(_mk_report())
    assert lvl == Readiness.RUNNABLE and gaps
    lvl, gaps = classify(_mk_report(metrics=dict(INSTR)))
    assert lvl == Readiness.INSTRUMENTED
    lvl, gaps = classify(
        _mk_report(metrics={**INSTR, "artifact_digest": "abc", "seed": 0})
    )
    assert lvl == Readiness.REPRODUCIBLE and not gaps


def test_reproduction_verification():
    a = _mk_report(metrics={**INSTR, "artifact_digest": "abc", "seed": 0})
    b = _mk_report(metrics={**INSTR, "artifact_digest": "abc", "seed": 0})
    c = _mk_report(metrics={**INSTR, "artifact_digest": "zzz", "seed": 0})
    assert verify_reproduction(a, b)
    assert not verify_reproduction(a, c)


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def test_regression_detection_fig4():
    """Synthetic GRAPH500-style series: stable, then a -20% step change."""
    rng = np.random.default_rng(0)
    base = 100 + rng.normal(0, 0.5, 30)
    drop = 80 + rng.normal(0, 0.5, 10)
    series = [(float(i), float(v)) for i, v in enumerate(np.concatenate([base, drop]))]
    regs = analysis.detect_regressions(series)
    assert regs and regs[0].index == 30
    assert abs(regs[0].relative + 0.2) < 0.05


def test_no_false_positives_on_stable_series():
    rng = np.random.default_rng(1)
    series = [(float(i), float(100 + rng.normal(0, 1.0))) for i in range(50)]
    # Noise up to ~3 sigma must not flag with the default 4-sigma + 5% gate.
    assert analysis.detect_regressions(series) == []


def test_strong_scaling_bands():
    # Perfect scaling except the largest point at 50%.
    points = {1: 100.0, 2: 50.0, 4: 25.0, 8: 25.0}
    table = analysis.strong_scaling(points)
    assert table[4]["within_band"] and not table[8]["within_band"]
    assert abs(table[8]["efficiency"] - 0.5) < 1e-9


def test_weak_scaling():
    table = analysis.weak_scaling({1: 10.0, 8: 11.0, 64: 20.0})
    assert table[8]["within_band"] and not table[64]["within_band"]


def test_csv_table_i_columns():
    csv = analysis.to_csv([_mk_report(metrics={"bw": 5.0})])
    header = csv.splitlines()[0].split(",")
    for col in analysis.TABLE_I_COLUMNS:
        assert col in header
    assert "additional_bw" in header


# ---------------------------------------------------------------------------
# energy
# ---------------------------------------------------------------------------

def test_energy_scope_trim_fig8():
    trace = energy.synth_power_trace(TPU_V5E, steady_power=260.0, n_samples=64, ramp=8)
    s, e = energy.trim_scope(trace)
    assert 4 <= s <= 10 and 54 <= e <= 64  # ramps excluded
    scoped = energy.scoped_energy(trace, dt_s=1.0)
    full = sum(trace)
    assert scoped["scoped_energy_j"] < full  # documented underestimate


def test_energy_sweet_spot_fig9():
    # Memory-bound workload: lowering frequency must save energy.
    sweep = energy.frequency_sweep(
        TPU_V5E, t_compute=0.2e-3, t_memory=1.0e-3, t_collective=0.1e-3, n_chips=256
    )
    assert energy.sweet_spot(sweep) < 1.0
    # Strongly compute-bound: sweet spot moves up relative to memory-bound.
    sweep_c = energy.frequency_sweep(
        TPU_V5E, t_compute=1.0e-3, t_memory=0.05e-3, t_collective=0.0, n_chips=256
    )
    assert energy.sweet_spot(sweep_c) >= energy.sweet_spot(sweep)


# ---------------------------------------------------------------------------
# orchestrators (fake harness for speed)
# ---------------------------------------------------------------------------

class FakeHarness(Harness):
    name = "fake"

    def __init__(self, fail_cells=(), flaky_cells=(), metric=1.0):
        self.fail_cells = set(fail_cells)
        self.flaky = dict.fromkeys(flaky_cells, True)
        self.metric = metric
        self.calls = []

    def run(self, spec, injections=None):
        self.calls.append((spec.cell, injections.describe() if injections else None))
        if spec.cell in self.fail_cells:
            raise RuntimeError("infrastructure failure")
        if self.flaky.get(spec.cell):
            self.flaky[spec.cell] = False  # fails once, then recovers
            raise RuntimeError("transient failure")
        r = new_report(system=spec.system, variant=spec.effective_variant(),
                       usecase=spec.shape, pipeline_id="p1")
        m = dict(INSTR)
        if injections and injections.overrides.get("knob"):
            m["step_time_s"] = 1.0 / float(injections.overrides["knob"])
        else:
            m["step_time_s"] = self.metric
        m["artifact_digest"] = "d0"
        m["seed"] = spec.seed
        r.data.append(DataEntry(success=True, runtime=0.1, metrics=m))
        return r


def _specs(n=3):
    return [BenchmarkSpec(arch=f"a{i}", shape="train_4k", system="sysA") for i in range(n)]


def test_execution_isolation_and_persistence(tmp_path):
    store = ResultStore(tmp_path)
    h = FakeHarness(fail_cells={"a1.train_4k.sysA"})
    ex = ExecutionOrchestrator(
        inputs={"prefix": "t", "record": True}, harness=h, store=store
    )
    results = ex.run_collection(_specs(3))
    assert [r.readiness for r in results] == [
        Readiness.REPRODUCIBLE, Readiness.FAILED, Readiness.REPRODUCIBLE
    ]
    # The failure did not prevent persistence of the other cells.
    assert len(store.query("t")) == 2
    assert results[1].error and "infrastructure failure" in results[1].error


def test_execution_retry_recovers_transient(tmp_path):
    h = FakeHarness(flaky_cells={"a0.train_4k.sysA"})
    ex = ExecutionOrchestrator(
        inputs={"prefix": "t"}, harness=h, store=ResultStore(tmp_path), max_retries=2
    )
    res = ex.run_cell(_specs(1)[0])
    assert res.readiness == Readiness.REPRODUCIBLE and res.attempts == 2


def test_feature_injection_sweep(tmp_path):
    store = ResultStore(tmp_path)
    ex = ExecutionOrchestrator(inputs={"prefix": "inj"}, harness=FakeHarness(), store=store)
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={"prefix": "inj"})
    results = fi.sweep(_specs(1)[0], override_knob="knob", values=[1, 2, 4])
    times = [r.report.data[0].metrics["step_time_s"] for r in results]
    assert times == [1.0, 0.5, 0.25]
    # Injections are recorded in the report parameters (provenance).
    assert store.query("inj")[0].parameter["injections"]["overrides"]["knob"] == 1


def test_post_processing_time_series_and_regression(tmp_path):
    store = ResultStore(tmp_path)
    rng = np.random.default_rng(2)
    t0 = time.time()
    for i in range(30):
        val = 1.0 if i < 20 else 1.5  # regression after 20 runs
        r = _mk_report(metrics={**INSTR, "step_time_s": val + rng.normal(0, 0.005)})
        r.experiment.timestamp = t0 + i
        store.append("bench.stream", r)
    pp = PostProcessingOrchestrator(store=store, inputs={"prefix": "evaluation.stream"})
    out = pp.time_series(source_prefix="bench.stream", data_labels=["step_time_s"])
    assert len(out["series"]["step_time_s"]) == 30
    assert out["regressions"]["step_time_s"], "regression must be detected"
    # Evaluation report persisted separately (decoupled post-processing).
    assert store.query("evaluation.stream")


def test_post_processing_machine_comparison(tmp_path):
    store = ResultStore(tmp_path)
    for sysname, val in [("jedi", 1.0), ("jureca", 2.0)]:
        r = _mk_report(system=sysname, metrics={"step_time_s": val})
        store.append(f"cmp.{sysname}", r)
    pp = PostProcessingOrchestrator(store=store, inputs={"prefix": "evaluation.cmp"})
    out = pp.machine_comparison(
        selectors=[{"prefix": "cmp.jedi"}, {"prefix": "cmp.jureca"}],
        metric="step_time_s",
    )
    assert out["table"]["jedi"]["median"] == 1.0
    assert out["table"]["jureca"]["median"] == 2.0
    assert "machine comparison" in out["markdown"]
