"""Tests for the regression-gating subsystem: detectors, baseline lifecycle,
gate exit codes through the CI/CD layer, and the protocol envelope."""

import json
import math

import pytest

from repro.core import analysis
from repro.core.cicd import component_dag, main as cicd_main, parse_pipeline_text
from repro.core.protocol import (
    DataEntry,
    ProtocolError,
    new_report,
    unwrap_envelope,
    wrap_envelope,
)
from repro.core.regression import (
    FAIL,
    PASS,
    WARN,
    BaselineManager,
    GateError,
    GateSpec,
    MetricSpec,
    RegressionGate,
    Verdict,
    get_detector,
    worst,
)
from repro.core.store import ResultStore

STABLE = [1.0, 1.02, 0.99, 1.01, 1.0, 0.98, 1.03, 1.0, 1.01, 0.99]


def _append(store, prefix, value, metric="step_time_s", system="t",
            success=True):
    r = new_report(system=system, variant="v", usecase="u", pipeline_id="p")
    r.data.append(DataEntry(success=success, runtime=max(value, 0.0),
                            metrics={metric: value}))
    store.append(prefix, r)


def _seed(store, prefix, values, **kw):
    for v in values:
        _append(store, prefix, v, **kw)


# ---------------------------------------------------------------------------
# metric specs + verdicts
# ---------------------------------------------------------------------------

def test_metric_spec_parse():
    m = MetricSpec.parse("step_time_s")
    assert (m.name, m.direction, m.tolerance) == ("step_time_s", "lower", 0.05)
    m = MetricSpec.parse("tokens_per_s:higher", tolerance=0.1)
    assert (m.direction, m.tolerance) == ("higher", 0.1)
    m = MetricSpec.parse("x:lower:0.2")
    assert m.tolerance == 0.2
    with pytest.raises(GateError):
        MetricSpec.parse("x:sideways")


def test_metric_spec_direction_and_effect():
    lower = MetricSpec("t", "lower", 0.05)
    higher = MetricSpec("tput", "higher", 0.05)
    assert lower.effect(1.2, 1.0) == pytest.approx(0.2)    # slower = worse
    assert higher.effect(1.2, 1.0) == pytest.approx(-0.2)  # faster = better
    assert higher.effect(0.8, 1.0) == pytest.approx(0.2)
    # Zero baseline: infinite relative change, not a silent zero.
    assert lower.effect(1.0, 0.0) == math.inf
    assert lower.effect(0.0, 0.0) == 0.0


def test_verdict_round_trip():
    v = Verdict(FAIL, "cusum", "step_time_s", "p", effect=0.5,
                confidence=0.99, baseline_n=10, candidate_n=2,
                change_seq=12, detail="d")
    doc = json.loads(json.dumps(v.to_dict()))
    assert Verdict.from_dict(doc) == v
    # Unknown keys from a future schema are tolerated.
    doc["novel_field"] = 1
    assert Verdict.from_dict(doc) == v


def test_worst_ordering():
    assert worst([]) == PASS
    assert worst([PASS, WARN, PASS]) == WARN
    assert worst([WARN, FAIL, PASS]) == FAIL


# ---------------------------------------------------------------------------
# detectors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mad", "bootstrap", "cusum"])
def test_detectors_pass_on_stable(name):
    det = get_detector(name)
    v = det.verdict(STABLE, [1.0, 1.01], MetricSpec("step_time_s"),
                    baseline_seqs=list(range(10)), candidate_seqs=[10, 11])
    assert v.status == PASS, v


@pytest.mark.parametrize("name", ["mad", "bootstrap"])
def test_window_detectors_fail_on_slowdown(name):
    det = get_detector(name)
    v = det.verdict(STABLE, [2.0, 2.1], MetricSpec("step_time_s"))
    assert v.status == FAIL
    assert v.effect > 0.5 and v.confidence >= 0.9


def test_cusum_localizes_change_point():
    det = get_detector("cusum")
    series = STABLE + STABLE + [5.0] * 6
    v = det.verdict(series[:-2], series[-2:], MetricSpec("step_time_s"),
                    baseline_seqs=list(range(len(series) - 2)),
                    candidate_seqs=[len(series) - 2, len(series) - 1])
    assert v.status == FAIL
    assert v.change_seq == 20  # first slow point
    assert v.effect > 1.0


def test_higher_is_better_direction():
    spec = MetricSpec("tokens_per_s", "higher", 0.05)
    det = get_detector("mad")
    drop = det.verdict([100.0] * 8, [50.0], spec)
    rise = det.verdict([100.0] * 8, [200.0], spec)
    assert drop.status == FAIL and drop.effect == pytest.approx(0.5)
    assert rise.status == PASS and rise.effect < 0


def test_detectors_are_deterministic():
    for name in ("bootstrap", "cusum"):
        det = get_detector(name)
        a = det.verdict(STABLE, [1.5], MetricSpec("m"))
        b = get_detector(name).verdict(STABLE, [1.5], MetricSpec("m"))
        assert a == b


def test_unknown_detector_rejected():
    with pytest.raises(GateError):
        get_detector("ouija")
    with pytest.raises(GateError):
        GateSpec.from_inputs({"source_prefix": "p", "detectors": "ouija"})


# ---------------------------------------------------------------------------
# protocol envelope
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_envelope_round_trip_through_store(tmp_path, backend):
    store = ResultStore(tmp_path / backend, backend=backend)
    payload = {"metric": "step_time_s", "values": [1.0, 2.0], "pinned": False,
               "n": 2, "note": None}
    rep = wrap_envelope("baseline", payload, system="mgr", source="src.p",
                        variant="step_time_s")
    store.append("baseline.src.p", rep)
    got = store.latest("baseline.src.p", variant="step_time_s")
    kind, back = unwrap_envelope(got)
    assert kind == "baseline" and back == payload
    # Finite numeric payload values are mirrored into metrics.
    assert got.data[0].metrics == {"n": 2.0}


def test_unwrap_rejects_plain_report():
    r = new_report(system="s", variant="v")
    with pytest.raises(ProtocolError):
        unwrap_envelope(r)
    with pytest.raises(ProtocolError):
        wrap_envelope("k", "not-a-dict")  # type: ignore[arg-type]


# ---------------------------------------------------------------------------
# baseline manager lifecycle
# ---------------------------------------------------------------------------

def test_baseline_promote_rolls_window(tmp_path):
    store = ResultStore(tmp_path)
    mgr = BaselineManager(store, window=4)
    mgr.promote("p", "m", [1.0, 2.0, 3.0], [0, 1, 2])
    b = mgr.promote("p", "m", [4.0, 5.0], [3, 4])
    assert b.values == [2.0, 3.0, 4.0, 5.0] and b.seqs == [1, 2, 3, 4]
    assert mgr.current("p", "m").values == [2.0, 3.0, 4.0, 5.0]


def test_baseline_promote_dedupes_rejudged_sequences(tmp_path):
    store = ResultStore(tmp_path)
    mgr = BaselineManager(store, window=8)
    mgr.promote("p", "m", [1.0, 2.0], [0, 1])
    # Re-promoting the same sequences (a gate re-run over an unchanged
    # store) must be a no-op, not window-filling duplication.
    b = mgr.promote("p", "m", [1.0, 2.0], [0, 1])
    assert b.values == [1.0, 2.0] and b.seqs == [0, 1]
    # Same-sequence duplicates within one batch (multi-entry report) stay.
    b = mgr.promote("p", "m", [3.0, 3.5], [2, 2])
    assert b.values == [1.0, 2.0, 3.0, 3.5] and b.seqs == [0, 1, 2, 2]


def test_gate_rerun_on_unchanged_store_is_stable(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", STABLE)
    gate = _gate("p")
    gate.run(store)
    first = BaselineManager(store).current("p", "step_time_s")
    for _ in range(5):
        assert gate.run(store)["status"] == PASS
    after = BaselineManager(store).current("p", "step_time_s")
    assert (after.values, after.seqs) == (first.values, first.seqs)


def test_baseline_pin_freezes_until_unpin(tmp_path):
    store = ResultStore(tmp_path)
    mgr = BaselineManager(store, window=8)
    mgr.pin("p", "m", values=[1.0, 1.0], seqs=[0, 1], commit="good")
    after = mgr.promote("p", "m", [9.0], [2])  # must not roll a pinned ref
    assert after.pinned and after.values == [1.0, 1.0] and after.commit == "good"
    mgr.unpin("p", "m")
    rolled = mgr.promote("p", "m", [9.0], [2])
    assert not rolled.pinned and rolled.values == [1.0, 1.0, 9.0]


def test_baseline_expire_and_pin_from_history(tmp_path):
    store = ResultStore(tmp_path)
    mgr = BaselineManager(store)
    _seed(store, "p", [1.0, 2.0, 3.0, 4.0])
    b = mgr.pin("p", "step_time_s", last=2)
    assert b.values == [3.0, 4.0] and b.seqs == [2, 3] and b.pinned
    mgr.expire("p", "step_time_s")
    assert mgr.current("p", "step_time_s") is None
    with pytest.raises(GateError):
        mgr.unpin("p", "step_time_s")


# ---------------------------------------------------------------------------
# the gate
# ---------------------------------------------------------------------------

def _gate(source, **kw):
    inputs = {"source_prefix": source, "metrics": ["step_time_s"],
              "candidate": 1, "tolerance": 0.2, "min_points": 4}
    inputs.update(kw)
    return RegressionGate.from_inputs(inputs)


def test_gate_insufficient_history_passes(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", [1.0, 1.0])
    s = _gate("p").run(store)
    assert s["status"] == PASS
    assert "insufficient history" in s["gates"][0]["verdicts"][0]["detail"]


def test_gate_ignores_failed_runs(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", STABLE)
    _append(store, "p", 50.0, success=False)  # crashed run, huge bogus value
    _append(store, "p", 1.0)
    s = _gate("p").run(store)
    assert s["status"] == PASS


def test_gate_fail_defends_baseline(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", STABLE)
    gate = _gate("p")
    assert gate.run(store)["status"] == PASS
    good = BaselineManager(store).current("p", "step_time_s")
    _seed(store, "p", [5.0] * 4)
    s = gate.run(store)
    assert s["status"] == FAIL
    assert s["gates"][0]["change_seq"] == 10  # first slow store sequence
    # The failing candidate must NOT have been promoted into the baseline.
    after = BaselineManager(store).current("p", "step_time_s")
    assert after.values == good.values


def test_gate_warn_only_demotes_fail(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", STABLE + [5.0])
    s = _gate("p", warn_only=True).run(store)
    assert s["status"] == WARN
    assert s["gates"][0]["warn_only"] is True


def test_pinned_baseline_override(tmp_path):
    """A pinned reference catches a slow drift that the rolling baseline
    would have absorbed."""
    store = ResultStore(tmp_path)
    _seed(store, "p", [3.0] * 12)  # drifted state is all the store knows
    rolling = _gate("p").run(store)
    assert rolling["status"] == PASS  # rolling baseline: 3.0 looks normal
    BaselineManager(store).pin("p", "step_time_s", values=[1.0] * 8,
                               seqs=list(range(8)), commit="known-good")
    pinned = _gate("p").run(store)
    assert pinned["status"] == FAIL
    assert pinned["gates"][0]["baseline"]["pinned"] is True
    BaselineManager(store).expire("p", "step_time_s")
    assert _gate("p").run(store)["status"] == PASS


def test_gate_records_verdict_envelope(tmp_path):
    store = ResultStore(tmp_path)
    _seed(store, "p", STABLE)
    _gate("p").run(store)
    rec = store.latest("gate.p")
    kind, payload = unwrap_envelope(rec)
    assert kind == "gate-verdict" and payload["status"] == PASS


# ---------------------------------------------------------------------------
# CI/CD integration: DAG placement + exit codes
# ---------------------------------------------------------------------------

GATE_YML = """\
include:
  - component: gate@v1
    inputs:
      source_prefix: "t.gate"
      metrics: [step_time_s]
      candidate: 1
      tolerance: 0.2
      min_points: 4
"""

EXEC_PLUS_GATE_YML = """\
include:
  - component: execution@v3
    inputs:
      prefix: "t.gate"
      arch: "a0"
  - component: gate@v1
    inputs:
      source_prefix: "t.gate"
      metrics: [step_time_s]
"""


def test_gate_waits_for_its_producers():
    calls = parse_pipeline_text(EXEC_PLUS_GATE_YML)
    assert [c.name for c in calls] == ["execution", "gate"]
    assert component_dag(calls) == [[], [0]]


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_cicd_gate_exit_codes(tmp_path, capsys, backend):
    """The acceptance path: identical history passes (exit 0), an appended
    synthetic slowdown fails (exit 3) with the offending prefix/metric and
    change-point sequence in gate_report.json."""
    yml = tmp_path / "gate.yml"
    yml.write_text(GATE_YML)
    store_root = tmp_path / "store"
    report = tmp_path / "gate_report.json"
    store = ResultStore(store_root, backend=backend)
    _seed(store, "t.gate", STABLE)

    argv = [str(yml), "--store", str(store_root), "--store-backend", backend,
            "--gate", "--gate-report", str(report)]
    assert cicd_main(argv) == 0
    doc = json.loads(report.read_text())
    assert doc["status"] == PASS and doc["exit_code"] == 0
    assert report.with_suffix(".md").exists()

    _seed(store, "t.gate", [5.0] * 4)
    assert cicd_main(argv) == 3
    doc = json.loads(report.read_text())
    assert doc["status"] == FAIL and doc["exit_code"] == 3
    g = doc["gates"][0]
    assert g["prefix"] == "t.gate" and g["metric"] == "step_time_s"
    assert g["change_seq"] == 10  # first injected sequence
    assert "fail" in report.with_suffix(".md").read_text()
    capsys.readouterr()


def test_gate_report_is_strict_json_on_zero_baseline(tmp_path, capsys):
    """A zero-valued baseline metric yields an infinite effect; the written
    report must still be strict JSON (no bare ``Infinity`` token)."""
    yml = tmp_path / "gate.yml"
    yml.write_text(GATE_YML)
    store = ResultStore(tmp_path / "store")
    _seed(store, "t.gate", [0.0] * 8 + [1.0] * 2)
    report = tmp_path / "gate_report.json"
    code = cicd_main([str(yml), "--store", str(tmp_path / "store"),
                      "--gate", "--gate-report", str(report)])
    def no_constants(s):
        raise AssertionError(f"non-standard JSON token {s!r} in report")
    doc = json.loads(report.read_text(), parse_constant=no_constants)
    assert code == 3 and doc["status"] == FAIL
    assert any(v["effect"] == "inf" for g in doc["gates"]
               for v in g["verdicts"])
    capsys.readouterr()


def test_detector_params_from_inputs():
    spec = GateSpec.from_inputs({
        "source_prefix": "p",
        "detector_params": {"bootstrap": {"n_boot": 50}},
        "mad.z_threshold": 6.0,
    })
    assert spec.detector_params == {"bootstrap": {"n_boot": 50},
                                    "mad": {"z_threshold": 6.0}}
    # And dotted keys survive the YAML-subset parser.
    calls = parse_pipeline_text(
        "include:\n"
        "  - component: gate@v1\n"
        "    inputs:\n"
        "      source_prefix: \"p\"\n"
        "      mad.z_threshold: 6\n"
    )
    assert GateSpec.from_inputs(calls[0].inputs).detector_params == {
        "mad": {"z_threshold": 6}}


def test_cicd_without_gate_flag_keeps_seed_exit_semantics(tmp_path, capsys):
    yml = tmp_path / "gate.yml"
    yml.write_text(GATE_YML)
    store = ResultStore(tmp_path / "store")
    _seed(store, "t.gate", STABLE + [5.0] * 4)
    # Gate component runs and reports fail, but without --gate the CLI keeps
    # the seed's 0/1 semantics.
    assert cicd_main([str(yml), "--store", str(tmp_path / "store")]) == 0
    capsys.readouterr()


def test_regression_cli_lifecycle(tmp_path, capsys):
    from repro.core.regression import main as reg_main

    store = str(tmp_path / "store")
    s = ResultStore(store)
    _seed(s, "p", STABLE)
    assert reg_main(["--store", store, "gate", "p", "--tolerance", "0.2",
                     "--min-points", "4"]) == 0
    assert reg_main(["--store", store, "pin", "p", "step_time_s",
                     "--last", "4", "--commit", "abc"]) == 0
    assert reg_main(["--store", store, "show", "p"]) == 0
    out = capsys.readouterr().out
    assert '"pinned": true' in out and "abc" in out
    _seed(s, "p", [5.0] * 4)
    assert reg_main(["--store", store, "gate", "p", "--tolerance", "0.2",
                     "--min-points", "4",
                     "--report", str(tmp_path / "r.json")]) == 3
    assert json.loads((tmp_path / "r.json").read_text())["status"] == FAIL
    capsys.readouterr()


# ---------------------------------------------------------------------------
# store tail + analysis edge cases (satellites)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_query_last_slices_index_before_fetch(tmp_path, backend):
    store = ResultStore(tmp_path, backend=backend)
    _seed(store, "p", [float(i) for i in range(10)])
    pairs = store.query_with_entries("p", last=3)
    assert [e.seq for e, _ in pairs] == [7, 8, 9]
    assert [r.data[0].metrics["step_time_s"] for _, r in pairs] == [7.0, 8.0, 9.0]
    assert store.query_with_entries("p", last=0) == []
    assert len(store.query("p")) == 10


def test_detect_regressions_edge_cases():
    # Empty and singleton series must not raise.
    assert analysis.detect_regressions([]) == []
    assert analysis.detect_regressions([(0.0, 1.0)]) == []
    # A degenerate window is clamped, not a crash: the doubled point may
    # legitimately flag, but nothing raises and relatives stay well-defined.
    regs = analysis.detect_regressions([(0.0, 1.0), (1.0, 2.0)], window=0)
    assert all(math.isfinite(r.relative) for r in regs)


def test_regression_relative_zero_baseline():
    r = analysis.Regression(index=1, timestamp=0.0, value=1.0, baseline=0.0,
                            sigma=1.0)
    assert r.relative == math.inf
    r = analysis.Regression(index=1, timestamp=0.0, value=-1.0, baseline=0.0,
                            sigma=1.0)
    assert r.relative == -math.inf
    r = analysis.Regression(index=1, timestamp=0.0, value=0.0, baseline=0.0,
                            sigma=1.0)
    assert r.relative == 0.0
