"""Property-based tests (hypothesis) on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import analysis, energy
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.hardware import TPU_V5E
from repro.models import params as P
from repro.train import optimizer as O
from repro import configs


# ---------------------------------------------------------------------------
# checkpoint: save/restore is the identity for arbitrary trees & dtypes
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(min_value=1, max_value=8), min_size=1, max_size=3),
    dtype=st.sampled_from(["float32", "bfloat16", "int32", "int8"]),
    seed=st.integers(min_value=0, max_value=99),
)
def test_checkpoint_identity_property(tmp_path_factory, shape, dtype, seed):
    tmp = tmp_path_factory.mktemp("ckpt")
    rng = np.random.default_rng(seed)
    arr = jnp.asarray(rng.standard_normal(tuple(shape)) * 100, dtype=dtype)
    mgr = CheckpointManager(tmp)
    mgr.save(1, {"x": {"y": arr}})
    out = mgr.restore(1)["x"]["y"]
    assert out.dtype == arr.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(arr))


# ---------------------------------------------------------------------------
# optimizer: q8 moment encode/decode error bound holds for any scale
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    scale=st.floats(min_value=1e-8, max_value=1e4),
    n=st.integers(min_value=1, max_value=600),
    seed=st.integers(min_value=0, max_value=20),
)
def test_q8_error_bound_property(scale, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, n)) * scale, jnp.float32)
    dec = O.q8_decode(O.q8_encode(x), x.shape)
    # Block-wise bound: error <= blockmax/127 (+ float slack).
    xb = np.asarray(x)
    err = np.abs(np.asarray(dec) - xb)
    for i in range(3):
        for b0 in range(0, n, O.Q8_BLOCK):
            blk = xb[i, b0 : b0 + O.Q8_BLOCK]
            bound = max(np.abs(blk).max(), 1e-12) / 127.0 * 1.02 + 1e-12
            assert err[i, b0 : b0 + O.Q8_BLOCK].max() <= bound


# ---------------------------------------------------------------------------
# data pipeline: disjointness and determinism across host/step/seed space
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=50),
    step=st.integers(min_value=0, max_value=50),
)
def test_data_pure_function_property(seed, step):
    cfg = dataclasses.replace(
        configs.get_smoke("glm4-9b"), vocab_size=64, d_model=32
    )
    d1 = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=2, seed=seed))
    d2 = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=2, seed=seed))
    assert jnp.array_equal(d1.batch(step)["tokens"], d2.batch(step)["tokens"])


# ---------------------------------------------------------------------------
# analysis: regression detector never fires on constant series, always fires
# on a large sustained step
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    level=st.floats(min_value=0.1, max_value=1e6),
    n=st.integers(min_value=12, max_value=60),
)
def test_no_regression_on_constant_series(level, n):
    series = [(float(i), level) for i in range(n)]
    assert analysis.detect_regressions(series) == []


@settings(max_examples=20, deadline=None)
@given(
    level=st.floats(min_value=1.0, max_value=1e3),
    jump=st.floats(min_value=1.5, max_value=5.0),
    at=st.integers(min_value=10, max_value=25),
)
def test_regression_always_detected_on_step(level, jump, at):
    rng = np.random.default_rng(0)
    vals = [level * (1 + rng.normal(0, 1e-4)) for _ in range(at)]
    vals += [level * jump * (1 + rng.normal(0, 1e-4)) for _ in range(10)]
    series = [(float(i), v) for i, v in enumerate(vals)]
    regs = analysis.detect_regressions(series)
    assert regs and regs[0].index == at


# ---------------------------------------------------------------------------
# energy: monotonicity invariants of the power model
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    uc=st.floats(min_value=0, max_value=1),
    um=st.floats(min_value=0, max_value=1),
)
def test_power_model_bounds(uc, um):
    p = energy.power_model(TPU_V5E, uc, um)
    assert TPU_V5E.power_idle_w <= p <= (
        TPU_V5E.power_idle_w + TPU_V5E.power_peak_compute_w + TPU_V5E.power_peak_hbm_w
    )


# ---------------------------------------------------------------------------
# params: spec/init agreement for every architecture
# ---------------------------------------------------------------------------

def test_init_matches_specs_all_archs():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_smoke(arch)
        specs = dict(P.iter_specs(P.param_specs(cfg)))
        tree = P.init_params(cfg, jax.random.key(0))
        flat = P.flatten(tree)
        assert set(flat) == set(specs), arch
        for k, v in flat.items():
            assert tuple(v.shape) == specs[k].shape, (arch, k)
            assert str(v.dtype) == specs[k].dtype, (arch, k)
