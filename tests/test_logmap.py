"""The paper's §II-A logmap example as an onboarded benchmark."""

from repro.core.harness import BenchmarkSpec, Injections
from repro.core.logmap import VARIANTS, LogmapHarness, run_logmap
from repro.core.orchestrator import ExecutionOrchestrator, FeatureInjectionOrchestrator
from repro.core.readiness import Readiness, classify, verify_reproduction
from repro.core.store import ResultStore


def _spec(variant="large-intensity"):
    return BenchmarkSpec(arch="logmap", shape="train_4k", system="cpu-smoke",
                         variant=variant)


def test_logmap_deterministic_and_reproducible():
    h = LogmapHarness()
    r1 = h.run(_spec())
    r2 = h.run(_spec())
    level, gaps = classify(r1)
    assert level == Readiness.REPRODUCIBLE, gaps
    assert verify_reproduction(r1, r2)


def test_logmap_variants_scale_work():
    base = run_logmap(**VARIANTS["small"])
    big_i = run_logmap(**VARIANTS["large-intensity"])
    big_w = run_logmap(**VARIANTS["large-workload"])
    assert big_i["iterations"] == 3 * base["iterations"]
    assert big_w["elements"] == 100 * base["elements"]


def test_logmap_through_orchestrators(tmp_path):
    """The paper's §II-C flow: execution + parameter injection for logmap."""
    store = ResultStore(tmp_path)
    ex = ExecutionOrchestrator(
        inputs={"prefix": "jedi.strong.tiny", "record": True},
        harness=LogmapHarness(), store=store,
    )
    res = ex.run_cell(_spec("large-intensity"))
    assert res.readiness == Readiness.REPRODUCIBLE
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={"prefix": "jedi.strong.tiny"})
    sweep = fi.sweep(_spec("small"), override_knob="intensity", values=[0.5, 1.0, 2.0])
    iters = [r.report.data[0].metrics["iterations"] for r in sweep]
    assert iters == sorted(iters) and iters[2] == 4 * iters[0]
    assert len(store.query("jedi.strong.tiny")) == 4
