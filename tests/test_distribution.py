"""Distribution layer: rule resolution, HLO cost model, dry-run integration."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest
from _hypothesis_compat import given, settings, st

from repro.distributed import hlo
from repro.distributed.sharding import STRATEGIES, spec_for

ROOT = Path(__file__).resolve().parents[1]


class FakeMesh:
    axis_names = ("data", "model")
    class devices:  # noqa: D106 — shape-only stand-in
        shape = (4, 8)


MESH = FakeMesh()


# ---------------------------------------------------------------------------
# spec_for
# ---------------------------------------------------------------------------

def test_spec_for_basic():
    spec = spec_for((128, 64), ("vocab", "embed"), {"vocab": "model"}, MESH)
    assert tuple(spec) == ("model",)


def test_spec_for_divisibility_fallback():
    fb = []
    spec = spec_for((10, 64), ("q_heads", None), {"q_heads": "model"}, MESH, fb)
    assert tuple(spec) == ()  # 10 % 8 != 0 -> replicated
    assert fb


def test_spec_for_prefix_fallback():
    # 12 % (4*8) != 0 but 12 % 4 == 0 -> falls back to the 'data' prefix.
    spec = spec_for((12,), ("batch",), {"batch": ("data", "model")}, MESH)
    assert tuple(spec) in ((("data",),), ("data",))


def test_spec_for_no_axis_reuse():
    spec = spec_for(
        (32, 64), ("vocab", "ffn"), {"vocab": "model", "ffn": "model"}, MESH
    )
    assert tuple(spec) == ("model",)  # second use of 'model' dropped


@settings(max_examples=60, deadline=None)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=512), min_size=1, max_size=4),
    assign=st.lists(st.sampled_from([None, "data", "model", ("data", "model")]),
                    min_size=4, max_size=4),
)
def test_spec_for_property(dims, assign):
    """Property: resolved specs never reuse a mesh axis and always divide."""
    axes = [f"ax{i}" for i in range(len(dims))]
    rules = {a: assign[i] for i, a in enumerate(axes)}
    spec = spec_for(tuple(dims), tuple(axes), rules, MESH)
    sizes = {"data": 4, "model": 8}
    used = []
    for dim, part in zip(dims, tuple(spec)):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        total = 1
        for n in names:
            assert n not in used
            used.append(n)
            total *= sizes[n]
        assert dim % total == 0


def test_strategies_registered():
    assert {"tp_dp", "fsdp_tp", "fsdp_dp"} <= set(STRATEGIES)


# ---------------------------------------------------------------------------
# HLO cost model (hand-written module)
# ---------------------------------------------------------------------------

HLO_TEXT = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} parameter(1)
      %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%y), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
      ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
    }

    %cond (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %i0 = s32[] constant(0)
      %t0 = (s32[], f32[8,16]) tuple(%i0, %a)
      %w2 = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
    }
""")


def test_hlo_cost_model_loop_aware():
    cost = hlo.analyze(HLO_TEXT, n_devices=8)
    # dot: 2*8*16*16 = 4096 flops, x12 trips.
    assert cost.flops == pytest.approx(12 * 4096, rel=0.01)
    # all-reduce: 8*16*4 bytes * 2 * (3/4) ring, x12 trips.
    assert cost.collective_bytes == pytest.approx(12 * 512 * 2 * 0.75, rel=0.01)
    assert cost.loops.get("body") == 12
    assert cost.collective_count == 12


def test_hlo_group_size_parsing():
    assert hlo._group_size("replica_groups=[2,4]<=[8]", 8) == 4
    assert hlo._group_size("replica_groups={{0,1,2},{3,4,5}}", 8) == 3
    assert hlo._group_size("", 8) == 8


def test_hlo_shape_bytes():
    assert hlo._shape_bytes("bf16[4,8]{1,0}") == 64
    assert hlo._shape_bytes("(f32[2,2], s32[])") == 20
    assert hlo._shape_bytes("pred[]") == 1


# ---------------------------------------------------------------------------
# Dry-run integration (subprocess: needs its own device count)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_cell_end_to_end(tmp_path):
    """The real dry-run CLI on the cheapest cell, both meshes."""
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    for flag in ([], ["--multi-pod"]):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "mamba2-1.3b", "--shape", "decode_32k",
             "--out", str(tmp_path)] + flag,
            capture_output=True, text=True, timeout=560, env=env, cwd=ROOT,
        )
        assert proc.returncode == 0, proc.stderr[-1500:]
    import json

    rec1 = json.loads((tmp_path / "mamba2-1.3b.decode_32k.1pod.json").read_text())
    rec2 = json.loads((tmp_path / "mamba2-1.3b.decode_32k.2pod.json").read_text())
    assert rec1["status"] == "ok" and rec2["status"] == "ok"
    assert rec1["roofline"]["hlo_flops"] > 0
    assert rec1["roofline"]["fits"] is True
    # The pod axis must shard: per-device HBM halves on 2 pods (batch split).
    assert rec2["memory_analysis"]["hbm_required"] <= rec1["memory_analysis"]["hbm_required"] * 1.05
