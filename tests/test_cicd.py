"""Tests for the declarative CI/CD pipeline layer and monitoring exports."""

import json

import pytest

from repro.core import export
from repro.core.cicd import (
    ComponentCall,
    PipelineError,
    parse_pipeline_text,
    run_pipeline,
)
from repro.core.harness import BenchmarkSpec, Harness
from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore

YML = """\
include:
  - component: execution@v3
    inputs:
      prefix: "t.pipe"
      arch: "a0"
      usecase: "train_4k"
      machine: "sysA"
      record: true
  - component: feature-injection@v3
    inputs:
      prefix: "t.pipe"
      arch: "a0"
      usecase: "train_4k"
      machine: "sysA"
      in_command: "export UCX_RNDV_THRESH=65536"
  - component: time-series@v3
    inputs:
      prefix: "evaluation.t"
      source_prefix: "t.pipe"
      data_labels: [step_time_s]
"""


class StubHarness(Harness):
    name = "stub"

    def run(self, spec: BenchmarkSpec, injections=None):
        r = new_report(system=spec.system, variant=spec.effective_variant(),
                       usecase=spec.shape, pipeline_id="p")
        m = {"step_time_s": 1.0}
        if injections and injections.env:
            m["injected_env"] = 1.0
        r.data.append(DataEntry(success=True, runtime=0.1, metrics=m))
        return r


def test_parse_scalar_floats_and_quoting():
    from repro.core.cicd import _parse_scalar

    # Leading-dot / exponent float forms (previously rejected as strings).
    assert _parse_scalar(".5") == 0.5
    assert _parse_scalar("1e-3") == 0.001
    assert _parse_scalar("-2.5E+2") == -250.0
    assert _parse_scalar("3.") == 3.0
    assert _parse_scalar("42") == 42 and isinstance(_parse_scalar("42"), int)
    # Quoting forces string — a quoted "true"/"123" must NOT be coerced.
    assert _parse_scalar('"true"') == "true"
    assert _parse_scalar("'123'") == "123"
    assert _parse_scalar("true") is True
    assert _parse_scalar("[1e-3, .5]") == [0.001, 0.5]
    assert _parse_scalar("plain-string") == "plain-string"


def test_parse_yaml_subset():
    calls = parse_pipeline_text(YML)
    assert [c.name for c in calls] == ["execution", "feature-injection", "time-series"]
    assert calls[0].inputs["prefix"] == "t.pipe"
    assert calls[0].inputs["record"] is True
    assert calls[2].inputs["data_labels"] == ["step_time_s"]


def test_parse_json_equivalent():
    doc = {"include": [{"component": "execution@v3",
                        "inputs": {"prefix": "x", "arch": "a"}}]}
    calls = parse_pipeline_text(json.dumps(doc))
    assert calls[0].name == "execution" and calls[0].version == 3


def test_rejects_unknown_component_and_version():
    with pytest.raises(PipelineError):
        parse_pipeline_text("include:\n  - component: nonsense@v3\n")
    with pytest.raises(PipelineError):
        parse_pipeline_text("include:\n  - component: execution@v9\n")
    with pytest.raises(PipelineError):
        parse_pipeline_text("# nothing\n")


def test_run_pipeline_end_to_end(tmp_path):
    store = ResultStore(tmp_path)
    results = run_pipeline(parse_pipeline_text(YML), store=store, harness=StubHarness())
    assert results[0]["component"] == "execution" and not results[0]["error"]
    # Env from in_command reached the harness via Injections.
    reports = store.query("t.pipe")
    assert any("injected_env" in d.metrics for r in reports for d in r.data)
    assert results[2]["points"]["step_time_s"] == 2


def test_exports(tmp_path):
    store = ResultStore(tmp_path)
    for i in range(3):
        r = new_report(system="s", variant="v", pipeline_id=f"p{i}")
        r.experiment.timestamp = 1000.0 + i
        r.data.append(DataEntry(success=True, runtime=0.5,
                                metrics={"step_time_s": 1.0 + i}, job_id=f"j{i}"))
        store.append("exp", r)
    g = export.grafana_table(store, "exp", "step_time_s")
    assert len(g["rows"]) == 3 and g["rows"][0][1] == 1.0
    jobs = export.llview_jobs(store, "exp")
    assert {j["jobid"] for j in jobs} == {"j0", "j1", "j2"}
    paths = export.write_exports(store, "exp", "step_time_s", tmp_path / "out")
    assert (tmp_path / "out").exists()
    art = export.ascii_timeseries(
        [(i, float(i % 5)) for i in range(40)], title="t", regressions=[30]
    )
    assert "!" in art and "t" in art
