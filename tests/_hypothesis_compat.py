"""Property-testing shim: real hypothesis when installed, otherwise a small
deterministic fallback.

The test image does not ship ``hypothesis`` and nothing may be pip-installed,
so the property tests import ``given``/``settings``/``st`` from here.  When
hypothesis is available it is used unchanged (full shrinking etc.); the
fallback samples a fixed-seed stream of examples per test, always including
the boundary assignments (all-min, all-max) that hypothesis would find first.
Only the strategy surface these tests use is implemented.
"""

from __future__ import annotations

try:  # pragma: no cover — exercised only where hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random
    import string

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng, mode):
            return self._sample(rng, mode)

    class _St:
        """Mini ``hypothesis.strategies`` namespace."""

        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**15) if min_value is None else int(min_value)
            hi = 2**15 if max_value is None else int(max_value)

            def f(rng, mode):
                if mode == "min":
                    return lo
                if mode == "max":
                    return hi
                return rng.randint(lo, hi)

            return _Strategy(f)

        @staticmethod
        def floats(min_value=None, max_value=None, allow_nan=None,
                   allow_infinity=None, width=64):
            lo = -1e6 if min_value is None else float(min_value)
            hi = 1e6 if max_value is None else float(max_value)

            def f(rng, mode):
                if mode == "min":
                    v = lo
                elif mode == "max":
                    v = hi
                else:
                    v = rng.uniform(lo, hi)
                if width == 32:
                    v = float(np.float32(v))
                    v = min(max(v, lo), hi)
                return v

            return _Strategy(f)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)

            def f(rng, mode):
                if mode == "min":
                    return elements[0]
                if mode == "max":
                    return elements[-1]
                return elements[rng.randrange(len(elements))]

            return _Strategy(f)

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            hi = (min_size + 4) if max_size is None else max_size

            def f(rng, mode):
                if mode == "min":
                    n = min_size
                elif mode == "max":
                    n = hi
                else:
                    n = rng.randint(min_size, hi)
                return [elem.sample(rng, mode) for _ in range(n)]

            return _Strategy(f)

        @staticmethod
        def characters(categories=(), **_kw):
            alphabet = ""
            if not categories:
                alphabet = string.ascii_letters
            if "Ll" in categories:
                alphabet += string.ascii_lowercase
            if "Lu" in categories:
                alphabet += string.ascii_uppercase
            if "Nd" in categories:
                alphabet += string.digits
            return _St.sampled_from(alphabet)

        @staticmethod
        def text(alphabet=None, min_size=0, max_size=None):
            if alphabet is None:
                alphabet = _St.sampled_from(string.ascii_letters + string.digits + "_-. ")
            elif isinstance(alphabet, str):
                alphabet = _St.sampled_from(alphabet)
            chars = _St.lists(alphabet, min_size=min_size, max_size=max_size)

            def f(rng, mode):
                return "".join(chars.sample(rng, mode))

            return _Strategy(f)

        @staticmethod
        def dictionaries(keys, values, min_size=0, max_size=None):
            hi = (min_size + 4) if max_size is None else max_size

            def f(rng, mode):
                n = min_size if mode == "min" else (hi if mode == "max"
                                                    else rng.randint(min_size, hi))
                out = {}
                for _ in range(n):
                    out[keys.sample(rng, mode)] = values.sample(rng, mode)
                return out

            return _Strategy(f)

        @staticmethod
        def booleans():
            return _St.sampled_from([False, True])

    st = _St()

    def given(**kwargs_st):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 20)
                rng = random.Random(0)
                modes = (["min", "max"] + ["rand"] * max(0, n - 2))[:n]
                for mode in modes:
                    drawn = {k: s.sample(rng, mode) for k, s in kwargs_st.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest must see only the non-drawn parameters (fixtures like
            # tmp_path_factory); the drawn ones would read as missing fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in kwargs_st
            ])
            return wrapper

        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco
